//! CLI contract tests against the real `heeperator` binary: exit codes
//! and stream discipline for the help/unknown-subcommand paths (a wrong
//! exit code lets CI scripts silently no-op).

use std::process::Command;

fn heeperator(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_heeperator"))
        .args(args)
        .output()
        .expect("spawn heeperator")
}

#[test]
fn no_arguments_prints_usage_and_exits_zero() {
    let out = heeperator(&[]);
    assert!(out.status.success(), "bare invocation is help, not an error");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("usage: heeperator"), "{stdout}");
    assert!(stdout.contains("scale"), "usage lists the scale subcommand");
}

#[test]
fn unknown_subcommand_exits_nonzero_with_usage_on_stderr() {
    let out = heeperator(&["frobnicate"]);
    assert!(!out.status.success(), "unknown subcommands must fail");
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("usage: heeperator"), "{stderr}");
    assert!(stderr.contains("frobnicate"), "names the offending word: {stderr}");
}

#[test]
fn bad_flag_value_exits_nonzero() {
    let out = heeperator(&["all", "--jobs", "lots"]);
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--jobs"), "{stderr}");
}

#[test]
fn scale_rejects_bad_tile_lists_without_simulating() {
    let out = heeperator(&["scale", "--tiles", "0"]);
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("tile"), "{stderr}");
}

#[test]
fn fuzz_tiny_clean_run_exits_zero_in_both_flag_spellings() {
    let out = heeperator(&["fuzz", "--seed", "11", "--budget", "2", "--max-insns", "16"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "clean fuzz run must exit 0: {stdout}");
    assert!(stdout.contains("no divergence"), "{stdout}");
    let out = heeperator(&["fuzz", "--seed=11", "--budget=2", "--max-insns=16"]);
    assert!(out.status.success(), "--flag=value spelling must behave identically");
    assert!(String::from_utf8_lossy(&out.stdout).contains("no divergence"));
}

#[test]
fn fuzz_bad_budget_exits_two() {
    let out = heeperator(&["fuzz", "--budget", "tons"]);
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--budget"), "{stderr}");
}

#[test]
fn fuzz_replay_of_missing_file_exits_two_with_usage_on_stderr() {
    let out = heeperator(&["fuzz", "--replay", "does-not-exist-fuzz-repro.json"]);
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("usage: heeperator"), "{stderr}");
    assert!(stderr.contains("does-not-exist-fuzz-repro.json"), "{stderr}");
}

#[test]
fn serve_selftest_json_is_byte_identical_across_runs() {
    let dir = std::env::temp_dir().join("heeperator-serve-cli-test");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let a = dir.join("serve-a.json");
    let b = dir.join("serve-b.json");
    for path in [&a, &b] {
        let out = heeperator(&[
            "serve",
            "--selftest",
            "--trace=mixed",
            "--seed=7",
            "--requests=8",
            "--json",
            path.to_str().unwrap(),
        ]);
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    }
    let ja = std::fs::read(&a).expect("first summary");
    let jb = std::fs::read(&b).expect("second summary");
    assert!(!ja.is_empty());
    assert_eq!(ja, jb, "serve --selftest --json must be byte-deterministic");
    let text = String::from_utf8(ja).unwrap();
    assert!(text.contains("\"schema\": \"heeperator-serve-v1\""), "{text}");
    assert!(text.contains("\"p99_latency_cycles\""), "{text}");
}

#[test]
fn serve_rejects_bad_invocations_with_exit_two() {
    for args in [
        &["serve", "--listen", "not-a-port"][..],
        &["serve", "--selftest", "--trace", "tsunami"][..],
        &["serve", "--tiles", "99"][..],
        &["serve", "--selftest", "--queue", "0"][..],
        &["serve", "--selftest", "--workers", "0"][..],
        &["serve", "--selftest", "--conns=0"][..],
        &["serve", "--selftest", "--load", "sideways"][..],
        &["serve", "--load", "closed"][..], // closed loop without --selftest
        &["serve", "--throughput", "--workers", "none"][..],
    ] {
        let out = heeperator(args);
        assert_eq!(out.status.code(), Some(2), "{args:?}");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(!stderr.is_empty(), "{args:?} must explain itself");
    }
}

#[test]
fn serve_closed_loop_selftest_is_byte_identical_in_both_flag_spellings() {
    let dir = std::env::temp_dir().join("heeperator-serve-closed-cli-test");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let a = dir.join("closed-a.json");
    let b = dir.join("closed-b.json");
    // One run per flag spelling: equal bytes proves both the determinism
    // of the closed-loop virtual clock and the `=` normalization.
    let out = heeperator(&[
        "serve",
        "--selftest",
        "--load",
        "closed",
        "--conns",
        "4",
        "--seed",
        "9",
        "--requests",
        "24",
        "--json",
        a.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = heeperator(&[
        "serve",
        "--selftest",
        "--load=closed",
        "--conns=4",
        "--seed=9",
        "--requests=24",
        "--json",
        b.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let ja = std::fs::read(&a).expect("first summary");
    let jb = std::fs::read(&b).expect("second summary");
    assert!(!ja.is_empty());
    assert_eq!(ja, jb, "closed-loop selftest must be byte-deterministic across spellings");
    let text = String::from_utf8(ja).unwrap();
    assert!(text.contains("\"trace\": \"closed\""), "{text}");
}

#[test]
fn serve_throughput_smoke_reports_live_schema_and_answers_everything() {
    let dir = std::env::temp_dir().join("heeperator-serve-tp-cli-test");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("live.json");
    let out = heeperator(&[
        "serve",
        "--throughput",
        "--workers=2",
        "--conns=2",
        "--requests=6",
        "--seed=7",
        "--json",
        path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&path).expect("live summary");
    assert!(text.contains("\"schema\":\"heeperator-serve-live-v1\""), "{text}");
    assert!(text.contains("\"workers\":2"), "{text}");
    assert!(text.contains("\"requests\":12"), "{text}");
    assert!(text.contains("\"completed\":12"), "{text}");
    assert!(text.contains("\"rejected\":0"), "{text}");
    assert!(text.contains("\"errored\":0"), "{text}");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("req/s"), "live report carries throughput: {stderr}");
}

#[test]
fn model_summary_is_byte_identical_in_both_flag_spellings() {
    let dir = std::env::temp_dir().join("heeperator-model-cli-test");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let a = dir.join("model-a.json");
    let b = dir.join("model-b.json");
    // One run per flag spelling: equal bytes proves both the model
    // pipeline's determinism and the `=` normalization.
    let out = heeperator(&[
        "model",
        "--graph",
        "matmul:p=32,add,relu,maxpool",
        "--tiles",
        "2",
        "--pipeline",
        "batch",
        "--seed",
        "7",
        "--json",
        a.to_str().unwrap(),
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("Multi-layer graph pipeline"), "{stdout}");
    assert!(stdout.contains("resident"), "report compares residency policies: {stdout}");
    let out = heeperator(&[
        "model",
        "--graph=matmul:p=32,add,relu,maxpool",
        "--tiles=2",
        "--pipeline=batch",
        "--seed=7",
        "--json",
        b.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let ja = std::fs::read(&a).expect("first summary");
    let jb = std::fs::read(&b).expect("second summary");
    assert!(!ja.is_empty());
    assert_eq!(ja, jb, "model --json must be byte-deterministic across spellings");
    let text = String::from_utf8(ja).unwrap();
    assert!(text.contains("\"schema\": \"heeperator-model-v1\""), "{text}");
    assert!(text.contains("\"resident\": {"), "{text}");
    assert!(text.contains("\"staged\": {"), "{text}");
    assert!(text.contains("\"dma_savings_cycles\""), "{text}");
    assert!(text.contains("\"boundary\": \"resident\""), "{text}");
}

#[test]
fn model_defaults_run_the_canonical_chain() {
    let out = heeperator(&["model"]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("matmul:p=32,add,relu,maxpool"), "{stdout}");
}

#[test]
fn model_rejects_bad_invocations_with_exit_two() {
    for (args, needle) in [
        (&["model", "--graph", "relu,matmul:p=32"][..], "--graph"),
        (&["model", "--graph=matmul:p=32,frobnicate"][..], "--graph"),
        (&["model", "--pipeline", "spiral"][..], "--pipeline"),
        (&["model", "--tiles", "0"][..], "--tiles"),
        (&["model", "--tiles=99"][..], "--tiles"),
        (&["model", "--sew", "7"][..], "--sew"),
    ] {
        let out = heeperator(args);
        assert_eq!(out.status.code(), Some(2), "{args:?}");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains(needle), "{args:?} must name the bad flag: {stderr}");
    }
}

#[test]
fn fuzz_replay_of_garbage_file_exits_two() {
    let dir = std::env::temp_dir().join("heeperator-fuzz-cli-test");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("not-a-repro.json");
    std::fs::write(&path, "{\"schema\": \"something-else\"}").expect("write garbage");
    let out = heeperator(&["fuzz", "--replay", path.to_str().unwrap()]);
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("not a fuzz repro"), "{stderr}");
}
