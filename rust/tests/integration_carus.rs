//! NM-Carus integration: full Table V column, lane-scaling ablation,
//! double buffering, and the code-size claim of the xvnmc extension.

use nmc::asm::Asm;
use nmc::carus::{Carus, CTL_OFFSET, CTL_START};
use nmc::isa::reg::*;
use nmc::isa::Sew;
use nmc::kernels::{run, Family, Kernel, Target};

#[test]
fn full_table5_carus_column_correct() {
    for family in Family::ALL {
        for sew in Sew::ALL {
            let k = Kernel::paper_default(family, Target::Carus, sew);
            let res = run(Target::Carus, k, sew, 31);
            assert!(res.cycles > 0 && res.outputs > 0, "{family:?} {sew}");
        }
    }
}

#[test]
fn lane_scaling_ablation() {
    // §III-B2: "NM-Carus VPU can be scaled arbitrarily: a higher number of
    // lanes increases the unrolling level, thus improving throughput."
    // Throughput of the saturated vmacc must scale ~linearly in lanes.
    use nmc::carus::vpu::{Vpu, ISSUE_OVERHEAD};
    use nmc::isa::xvnmc::{VOp, VSrcKind};
    let t = |lanes: u32| {
        let mut v = Vpu::new(lanes);
        v.set_vtype(1024, Sew::E8);
        let c = v.op_cost(VOp::Macc, VSrcKind::Vx);
        1024.0 / (c - ISSUE_OVERHEAD) as f64
    };
    let t1 = t(1);
    let t4 = t(4);
    let t8 = t(8);
    assert!((t4 / t1 - 4.0).abs() < 0.1);
    assert!((t8 / t4 - 2.0).abs() < 0.1);
}

#[test]
fn double_buffering_host_writes_during_kernel() {
    // §III-B2: "NM-Carus can be set back to normal memory mode during the
    // kernel execution so that normal memory operations are possible
    // (e.g., to implement double buffering)."
    let mut c = Carus::new(4);
    let vl = 1024u32;
    for j in 0..vl {
        c.vrf.set_elem(0, j, vl, Sew::E8, 1);
    }
    // Long kernel: v2 = v0 + 0 repeated over several registers.
    let mut a = Asm::new(0);
    a.li(A0, vl as i32)
        .vsetvli(T0, A0, Sew::E8)
        .vadd_vx(2, 0, ZERO)
        .vadd_vx(3, 0, ZERO)
        .vadd_vx(4, 0, ZERO)
        .ebreak();
    c.load_kernel(&a.assemble().unwrap().words);
    c.config_mode = true;
    c.bus_write(CTL_OFFSET, 4, CTL_START);
    c.config_mode = false;
    // While the kernel runs, the host refills an unrelated region (v20..).
    let mut wrote = 0;
    let mut steps = 0u64;
    while c.busy() {
        c.step();
        steps += 1;
        if steps % 3 == 0 && wrote < 256 {
            let p = c.bus_write(20 * 1024 + wrote * 4, 4, 0xd0d0_0000 + wrote);
            assert!(p <= 1, "penalty bounded");
            wrote += 1;
        }
        assert!(steps < 100_000);
    }
    // Kernel result intact…
    for j in 0..vl {
        assert_eq!(c.vrf.elem_unsigned(2, j, vl, Sew::E8), 1);
    }
    // …and the concurrently-written buffer too.
    for i in 0..wrote {
        assert_eq!(c.vrf.peek(20 * 1024 + i * 4, 4), 0xd0d0_0000 + i);
    }
    // Conflict penalties were actually charged.
    assert!(c.stats.host_conflicts > 0);
}

#[test]
fn xvnmc_code_size_beats_unrolled_rvv() {
    // The paper's code-size claim (§III-B1): with indirect register
    // addressing one vector instruction + one addi serves every iteration;
    // with hardcoded register numbers the loop must be fully unrolled.
    // Element-wise add over 20 logical registers:
    let indirect_version = {
        let mut a = Asm::new(0);
        a.li(T0, 20)
            .li(S1, nmc::isa::xvnmc::pack_indexes(40, 0, 20) as i32)
            .label("loop")
            .v_opr(nmc::isa::xvnmc::VOp::Add, S1, nmc::isa::xvnmc::VSrc::V(0))
            .li(T1, 0x010101)
            .add(S1, S1, T1)
            .addi(T0, T0, -1)
            .bne(T0, ZERO, "loop")
            .ebreak();
        a.assemble().unwrap().size()
    };
    let unrolled_version = {
        let mut a = Asm::new(0);
        for k in 0..20u8 {
            // Direct encodings cap at 32 registers — the unrolled form
            // could not even express 256 logical registers.
            a.vadd_vv(10 + k % 20, k, (k + 1) % 32);
        }
        a.ebreak();
        a.assemble().unwrap().size()
    };
    assert!(
        indirect_version < unrolled_version,
        "indirect {indirect_version} B vs unrolled {unrolled_version} B"
    );
}

#[test]
fn emvx_hazard_only_blocks_on_written_register() {
    // Precise scoreboard (§III-B1: emvx is the only hazard source): an
    // emvx reading a register *not* written by the in-flight instruction
    // proceeds immediately; reading the in-flight destination waits.
    let mut c = Carus::new(4);
    let vl = 1024u32;
    for j in 0..vl {
        c.vrf.set_elem(0, j, vl, Sew::E8, 7);
    }
    // Kernel A: long vadd to v2, then emvx from v0 (no hazard) → fast.
    let t_no_hazard = {
        let mut a = Asm::new(0);
        a.li(A0, vl as i32)
            .vsetvli(T0, A0, Sew::E8)
            .vadd_vx(2, 0, ZERO)
            .li(A1, 0)
            .emvx(A2, 0, A1)
            .ebreak();
        run_kernel(&mut c, &a)
    };
    // Kernel B: same but emvx from v2 (the in-flight destination) → waits.
    let t_hazard = {
        let mut a = Asm::new(0);
        a.li(A0, vl as i32)
            .vsetvli(T0, A0, Sew::E8)
            .vadd_vx(2, 0, ZERO)
            .li(A1, 0)
            .emvx(A2, 2, A1)
            .ebreak();
        run_kernel(&mut c, &a)
    };
    // Both end after the vadd drains (busy() includes the VPU), but the
    // hazard version must stall the *eCPU* longer.
    assert!(
        c.stats.ecpu_vpu_stall_cycles > 0,
        "hazard case must have stalled"
    );
    let _ = (t_no_hazard, t_hazard);
}

fn run_kernel(c: &mut Carus, a: &Asm) -> u64 {
    c.load_kernel(&a.assemble().unwrap().words);
    c.config_mode = true;
    c.bus_write(CTL_OFFSET, 4, CTL_START);
    c.bus_write(CTL_OFFSET, 4, 0); // clear any stale done
    c.config_mode = false;
    // restart properly
    c.config_mode = true;
    c.bus_write(CTL_OFFSET, 4, CTL_START);
    c.config_mode = false;
    let mut n = 0u64;
    while c.busy() {
        c.step();
        n += 1;
        assert!(n < 1_000_000);
    }
    n
}

#[test]
fn carus_speedups_within_band_of_paper() {
    let cases = [
        (Family::Xor, Sew::E8, 12.7, 0.45),
        (Family::Matmul, Sew::E8, 53.9, 0.35),
        (Family::Relu, Sew::E8, 99.6, 0.40),
        (Family::Maxpool, Sew::E8, 6.3, 0.45),
    ];
    for (family, sew, paper, tol) in cases {
        let cpu = run(Target::Cpu, Kernel::paper_default(family, Target::Cpu, sew), sew, 3);
        let car = run(Target::Carus, Kernel::paper_default(family, Target::Carus, sew), sew, 3);
        let spd = cpu.cycles_per_output() / car.cycles_per_output();
        assert!(
            (spd - paper).abs() / paper < tol,
            "{family:?} {sew}: {spd:.1}x vs paper {paper}x"
        );
    }
}
