//! End-to-end contract of `heeperator model` (DESIGN.md §14): a
//! multi-layer INT8 graph compiled onto two or more NM-Carus tiles must
//! reproduce the byte-identical outputs of its CPU-golden chain in both
//! pipeline modes and under both timing disciplines, and keeping the
//! inter-layer activations resident in tile SRAM must beat the forced
//! per-layer host-staging baseline on DMA activity — the quantified
//! claim the CI `model-smoke` job gates on.

use nmc::clock::{self, TimingMode};
use nmc::graph::{compile, Graph, Pipeline, CANONICAL};
use nmc::isa::Sew;
use nmc::sched::pipeline::{run_model, ModelRunResult, Residency};

/// The CPU-golden chain's final activation bytes, one per item.
fn golden_outputs(g: &Graph, items: u32) -> Vec<Vec<u8>> {
    (0..items).map(|i| g.golden_item(i).last().unwrap().expect.clone()).collect()
}

fn run(g: &Graph, tiles: u32, pipeline: Pipeline, residency: Residency) -> ModelRunResult {
    let sch = compile(g, tiles, pipeline).expect("chain lowers onto the tile array");
    run_model(&sch, residency)
        .unwrap_or_else(|e| panic!("{pipeline:?}/{}: {e}", residency.name()))
}

#[test]
fn canonical_chain_is_golden_identical_and_resident_saves_dma() {
    // 4-layer INT8 chain (matmul -> add -> relu -> maxpool) on 2 tiles:
    // every pipeline mode and timing discipline must agree byte-for-byte
    // with the CPU-golden chain, and the resident run must move fewer
    // DMA cycles than its forced-staged twin.
    let g = Graph::parse(CANONICAL, Sew::E8, 7).unwrap();
    let golden = golden_outputs(&g, 2);
    for pipeline in Pipeline::ALL {
        for mode in [TimingMode::Cycle, TimingMode::Event] {
            let resident =
                clock::with_mode(mode, || run(&g, 2, pipeline, Residency::Auto));
            let staged =
                clock::with_mode(mode, || run(&g, 2, pipeline, Residency::ForceStaged));
            let ctx = format!("{pipeline:?} under {mode:?}");
            assert_eq!(resident.outputs, golden, "{ctx}: resident vs CPU-golden");
            assert_eq!(staged.outputs, golden, "{ctx}: staged vs CPU-golden");
            assert_eq!(resident.resident_boundaries, 3, "{ctx}");
            assert_eq!(staged.resident_boundaries, 0, "{ctx}");
            assert!(
                resident.dma_active_cycles < staged.dma_active_cycles,
                "{ctx}: resident {} !< staged {}",
                resident.dma_active_cycles,
                staged.dma_active_cycles
            );
            assert!(
                resident.dma_transfers < staged.dma_transfers,
                "{ctx}: resident {} transfers !< staged {}",
                resident.dma_transfers,
                staged.dma_transfers
            );
        }
    }
}

#[test]
fn timing_disciplines_agree_on_every_model_counter() {
    // The event-driven core must be indistinguishable from the per-cycle
    // reference on the pipeline executor too, not just single kernels.
    let g = Graph::parse(CANONICAL, Sew::E8, 11).unwrap();
    for residency in [Residency::Auto, Residency::ForceStaged] {
        let cyc = clock::with_mode(TimingMode::Cycle, || {
            run(&g, 2, Pipeline::Layer, residency)
        });
        let evt = clock::with_mode(TimingMode::Event, || {
            run(&g, 2, Pipeline::Layer, residency)
        });
        let ctx = residency.name();
        assert_eq!(evt.outputs, cyc.outputs, "{ctx}: output bytes diverged");
        assert_eq!(evt.cycles, cyc.cycles, "{ctx}: makespan diverged");
        assert_eq!(evt.dma_active_cycles, cyc.dma_active_cycles, "{ctx}: dma diverged");
        assert_eq!(evt.dma_transfers, cyc.dma_transfers, "{ctx}: transfers diverged");
        assert_eq!(evt.bus_txns, cyc.bus_txns, "{ctx}: bus transactions diverged");
        assert_eq!(evt.energy, cyc.energy, "{ctx}: energy breakdown diverged");
        for (e, c) in evt.layers.iter().zip(cyc.layers.iter()) {
            assert_eq!(e.cycles, c.cycles, "{ctx}: per-layer cycles diverged");
            assert_eq!(
                e.dma_active_cycles, c.dma_active_cycles,
                "{ctx}: per-layer dma diverged"
            );
        }
    }
}

#[test]
fn wider_tile_arrays_and_staged_fallbacks_stay_golden() {
    // 4 tiles: layer pipeline wraps the chain around the array, batch
    // pipeline runs 4 items at once. A mid-chain maxpool forces its
    // consumer through the host-staging fallback even under Auto.
    let g = Graph::parse(CANONICAL, Sew::E8, 3).unwrap();
    for pipeline in Pipeline::ALL {
        let res = run(&g, 4, pipeline, Residency::Auto);
        assert_eq!(res.items, 4, "{pipeline:?}");
        assert_eq!(res.outputs, golden_outputs(&g, 4), "{pipeline:?}");
    }
    let fallback = Graph::parse("matmul:p=32,maxpool,relu", Sew::E8, 5).unwrap();
    let res = run(&fallback, 2, Pipeline::Layer, Residency::Auto);
    assert_eq!(res.staged_boundaries, 1, "maxpool output is multi-chunk");
    assert_eq!(res.outputs, golden_outputs(&fallback, 2));
}

#[test]
fn per_layer_accounting_adds_up() {
    let g = Graph::parse(CANONICAL, Sew::E8, 7).unwrap();
    let res = run(&g, 2, Pipeline::Batch, Residency::Auto);
    assert_eq!(res.layers.len(), 4);
    // Layer steps partition the run: per-layer counters sum to the whole.
    let layer_cycles: u64 = res.layers.iter().map(|l| l.cycles).sum();
    assert_eq!(layer_cycles, res.cycles, "layer cycles partition the makespan");
    let layer_dma: u64 = res.layers.iter().map(|l| l.dma_active_cycles).sum();
    assert_eq!(layer_dma, res.dma_active_cycles);
    let layer_tx: u64 = res.layers.iter().map(|l| l.dma_transfers).sum();
    assert_eq!(layer_tx, res.dma_transfers);
}
