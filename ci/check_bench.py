#!/usr/bin/env python3
"""Fold the perf-smoke measurements into BENCH_5.json and gate regressions.

Inputs:
  --scale scale.json         `heeperator scale --json` output: deterministic
                             simulated cycles + wall time per tile count.
  --bench-lines FILE.jsonl   benchlib JSON lines (one {"id", "median_ns",
                             "runs"} object per line) from the e2e bench
                             binaries run with BENCHLIB_JSON set.
  --baseline FILE.json       committed baseline. Gating compares the
                             *simulated* aggregate cycles (deterministic);
                             wall times are recorded but never gated.
  --out BENCH_5.json         merged machine-readable summary (uploaded as a
                             CI artifact; copy it over the baseline to
                             ratchet).

Gates (exit 1 on violation):
  * aggregate simulated cycles regress more than --max-regress (default
    10%) vs the baseline's aggregate_cycles;
  * the speedup at the largest tile count falls below --min-speedup, when
    given (the scale-out acceptance bar).

A missing baseline, or one marked {"bootstrap": true}, records the run
without gating — commit the uploaded BENCH_5.json as bench-baseline.json
to arm the gate.
"""

import argparse
import json
import sys


def read_json(path):
    with open(path) as f:
        return json.load(f)


def read_jsonl(path):
    # An explicitly-passed bench file that does not exist means the bench
    # plumbing broke (wrong cwd, renamed bench, crash before first write);
    # failing loudly beats a green run with silently-missing data.
    out = []
    try:
        f = open(path)
    except FileNotFoundError:
        raise SystemExit(f"FAIL: bench-lines file {path} not found (bench step broken?)")
    with f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    if not out:
        raise SystemExit(f"FAIL: bench-lines file {path} is empty — no measurements recorded")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", required=True)
    ap.add_argument("--bench-lines", default=None)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--out", required=True)
    ap.add_argument("--max-regress", type=float, default=0.10)
    ap.add_argument("--min-speedup", type=float, default=None)
    args = ap.parse_args()

    scale = read_json(args.scale)
    reports = list(scale.get("reports", []))
    aggregate = scale.get("aggregate_cycles")
    if aggregate is None:
        aggregate = sum(r.get("cycles", 0) for r in reports)

    for m in read_jsonl(args.bench_lines) if args.bench_lines else []:
        reports.append(
            {
                "id": m["id"],
                "cycles": None,  # wall-clock benchmark, no simulated cycles
                "wall_ms": round(m["median_ns"] / 1e6, 3),
                "runs": m.get("runs"),
            }
        )

    merged = {
        "schema": "heeperator-bench-v1",
        "reports": reports,
        "aggregate_cycles": aggregate,
    }
    with open(args.out, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}: {len(reports)} reports, aggregate {aggregate} simulated cycles")

    failures = []

    if args.min_speedup is not None:
        tiled = [r for r in reports if r.get("tiles") and r.get("speedup") is not None]
        if tiled:
            top = max(tiled, key=lambda r: r["tiles"])
            print(f"speedup at {top['tiles']} tiles: {top['speedup']:.2f}x (floor {args.min_speedup}x)")
            if top["speedup"] < args.min_speedup:
                failures.append(
                    f"speedup at {top['tiles']} tiles is {top['speedup']:.2f}x < {args.min_speedup}x"
                )

    try:
        baseline = read_json(args.baseline)
    except FileNotFoundError:
        baseline = None
    base_cycles = None if baseline is None else baseline.get("aggregate_cycles")
    if baseline is None or baseline.get("bootstrap") or not base_cycles:
        print("no armed baseline: recording only (commit BENCH_5.json as the baseline to gate)")
    else:
        delta = (aggregate - base_cycles) / base_cycles
        print(f"aggregate cycles: {aggregate} vs baseline {base_cycles} ({delta:+.1%})")
        if delta > args.max_regress:
            failures.append(
                f"aggregate simulated cycles regressed {delta:.1%} > {args.max_regress:.0%}"
            )
        elif delta < -args.max_regress:
            print("note: large improvement — consider ratcheting the committed baseline")

    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
