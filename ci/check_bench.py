#!/usr/bin/env python3
"""Fold the perf-smoke measurements into BENCH_6.json and gate regressions.

Inputs:
  --scale scale.json         `heeperator scale --json` output: deterministic
                             simulated cycles + wall time + simulator
                             throughput per tile count.
  --serve serve.json         `heeperator serve --selftest --json` output:
                             deterministic latency percentiles / queue and
                             batching stats from the virtual-time service
                             replay. Folded under the "serve" key of --out
                             and gated on p99 latency vs the baseline.
                             At least one of --scale / --serve is required.
  --live live.json           repeatable. `heeperator serve --throughput
                             --json` output (heeperator-serve-live-v1):
                             wall-clock req/s of the live multi-connection
                             path at one worker count. All entries fold
                             under the "serve_live" key of --out. Wall
                             clock is machine-dependent, so entries are
                             never compared against the baseline — only
                             the within-run worker-scaling ratio is gated
                             (--min-worker-speedup).
  --model model.json         `heeperator model --json` output
                             (heeperator-model-v1): deterministic cycle/DMA
                             totals of the resident-tensor run and its
                             forced-staged twin. Folded under the "model"
                             key of --out; with no --scale/--serve the
                             resident makespan is the gated aggregate.
  --diff scale-cycle.json    a second scale summary from the *other* timing
                             mode (`--timing cycle`). Every shared point must
                             report identical simulated cycles — the
                             cheap CI edition of tests/timing_equivalence.rs.
                             The wall-time ratio of the shared points is the
                             measured event-vs-cycle sim speedup.
  --bench-lines FILE.jsonl   benchlib JSON lines from the bench binaries run
                             with BENCHLIB_JSON set. Wall-time lines carry
                             {"id", "median_ns", "runs"}; rate lines carry
                             {"id", "throughput_per_s", "unit"} instead.
  --baseline FILE.json       baseline to gate against. Gating compares the
                             *simulated* aggregate cycles (deterministic);
                             wall times are recorded but never gated.
  --out BENCH_6.json         merged machine-readable summary (uploaded as a
                             CI artifact and cached as the armed baseline).

Gates (exit 1 on violation):
  * aggregate simulated cycles regress more than --max-regress (default
    10%) vs the baseline's aggregate_cycles;
  * the serve p99 latency regresses more than --max-latency-regress
    (default 10%) vs the baseline's serve.p99_latency_cycles, and the
    serve summary must be internally consistent (every request answered
    exactly once — completed + rejected + errored == requests);
  * the speedup at the largest tile count falls below --min-speedup, when
    given (the scale-out acceptance bar);
  * any --diff point disagrees on simulated cycles (timing-mode drift);
  * the event-vs-cycle sim speedup falls below --min-sim-speedup, when
    given (the event-driven timing core's acceptance bar);
  * any --live entry drops a request (completed + rejected + errored !=
    requests) or errors, and — when --min-worker-speedup is given — the
    req/s ratio of the highest-worker entry over the workers == 1 entry
    falls below the floor (the worker-pool acceptance bar; within-run,
    so machine-consistent like --min-sim-speedup);
  * the --model summary keeps no boundary resident, or the resident run
    fails to beat its forced-staged twin on aggregate DMA-active cycles
    (the graph IR's acceptance bar; within-run and deterministic). The
    resident makespan rides the aggregate-cycles gate vs the baseline.

Baseline arming: simulated cycles are deterministic and machine-
independent, so the first CI run's BENCH_6.json is a valid baseline for
every later run. The workflow caches it under an immutable key; a
committed bench-baseline.json without {"bootstrap": true} takes
precedence. A missing/bootstrap baseline records without gating.
"""

import argparse
import json
import sys


def read_json(path):
    with open(path) as f:
        return json.load(f)


def read_jsonl(path):
    # An explicitly-passed bench file that does not exist means the bench
    # plumbing broke (wrong cwd, renamed bench, crash before first write);
    # failing loudly beats a green run with silently-missing data.
    out = []
    try:
        f = open(path)
    except FileNotFoundError:
        raise SystemExit(f"FAIL: bench-lines file {path} not found (bench step broken?)")
    with f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    if not out:
        raise SystemExit(f"FAIL: bench-lines file {path} is empty — no measurements recorded")
    return out


def diff_timing_modes(reports, other, failures):
    """Point-wise cycle identity between the two timing modes, plus the
    wall-time ratio (the measured skip-ahead speedup). Returns the
    speedup, or None if no shared point has usable wall times."""
    theirs = {r["id"]: r for r in other.get("reports", []) if r.get("cycles") is not None}
    shared = [r for r in reports if r.get("cycles") is not None and r["id"] in theirs]
    if not shared:
        failures.append("--diff given but the summaries share no cycle-reporting points")
        return None
    wall_event = wall_cycle = 0.0
    for r in shared:
        o = theirs[r["id"]]
        if r["cycles"] != o["cycles"]:
            failures.append(
                f"timing modes disagree on {r['id']}: "
                f"{r['cycles']} vs {o['cycles']} simulated cycles"
            )
        wall_event += r.get("wall_ms") or 0.0
        wall_cycle += o.get("wall_ms") or 0.0
    print(f"timing diff: {len(shared)} shared points compared against {other.get('timing', '?')} mode")
    if wall_event <= 0.0 or wall_cycle <= 0.0:
        return None
    speedup = wall_cycle / wall_event
    print(f"event-vs-cycle sim speedup: {speedup:.1f}x ({wall_cycle:.1f} ms -> {wall_event:.1f} ms)")
    return speedup


def check_serve(serve, baseline, max_latency_regress, failures):
    """Structural sanity of a serve summary + the p99 latency gate.
    Latencies are simulated cycles from the virtual-time replay, so the
    comparison is deterministic — no wall-clock noise to absorb."""
    if serve.get("schema") != "heeperator-serve-v1":
        failures.append(f"serve summary has schema {serve.get('schema')!r}, "
                        "expected heeperator-serve-v1")
        return
    answered = serve.get("completed", 0) + serve.get("rejected", 0) + serve.get("errored", 0)
    if answered != serve.get("requests"):
        failures.append(
            f"serve summary drops requests: completed+rejected+errored = {answered} "
            f"but requests = {serve.get('requests')}"
        )
    if serve.get("errored", 0):
        failures.append(
            f"serve selftest errored on {serve['errored']} generated requests "
            "(the load generator only emits valid shapes)"
        )
    p99 = serve.get("p99_latency_cycles")
    base = None if baseline is None else baseline.get("serve", {}).get("p99_latency_cycles")
    print(f"serve: {serve.get('requests')} requests, {serve.get('batches')} batches, "
          f"p99 latency {p99} cycles")
    if not base:
        print("no armed serve baseline: recording p99 only")
        return
    delta = (p99 - base) / base
    print(f"serve p99 latency: {p99} vs baseline {base} ({delta:+.1%})")
    if delta > max_latency_regress:
        failures.append(
            f"serve p99 latency regressed {delta:.1%} > {max_latency_regress:.0%}"
        )


def check_live(entries, min_worker_speedup, failures):
    """Structural sanity of the live throughput entries + the worker-pool
    scaling gate. Wall-clock req/s is machine-dependent, so only the
    within-run ratio between worker counts is ever gated."""
    for e in entries:
        if e.get("schema") != "heeperator-serve-live-v1":
            failures.append(f"live summary has schema {e.get('schema')!r}, "
                            "expected heeperator-serve-live-v1")
            return
        answered = e.get("completed", 0) + e.get("rejected", 0) + e.get("errored", 0)
        if answered != e.get("requests"):
            failures.append(
                f"live run (workers={e.get('workers')}) drops requests: "
                f"completed+rejected+errored = {answered} but requests = {e.get('requests')}"
            )
        if e.get("errored", 0):
            failures.append(
                f"live run (workers={e.get('workers')}) errored on {e['errored']} requests"
            )
        print(f"serve live: workers={e.get('workers')} conns={e.get('conns')} "
              f"req/s={e.get('req_per_s')} ({e.get('completed')}/{e.get('requests')} completed)")
    if min_worker_speedup is None:
        return
    usable = [e for e in entries if e.get("req_per_s")]
    base = next((e for e in usable if e.get("workers") == 1), None)
    if base is None or len(usable) < 2:
        failures.append("--min-worker-speedup given but the --live entries lack a "
                        "workers == 1 run plus a multi-worker run")
        return
    top = max(usable, key=lambda e: e["workers"])
    speedup = top["req_per_s"] / base["req_per_s"]
    print(f"worker-pool speedup: {speedup:.2f}x at {top['workers']} workers "
          f"({base['req_per_s']:.1f} -> {top['req_per_s']:.1f} req/s, floor {min_worker_speedup}x)")
    if speedup < min_worker_speedup:
        failures.append(
            f"req/s with {top['workers']} workers is {speedup:.2f}x the 1-worker rate "
            f"< {min_worker_speedup}x"
        )


def check_model(model, failures):
    """Structural sanity of a model summary + the resident-vs-staged DMA
    gate. Both runs are deterministic simulated executions of the same
    schedule, so the comparison is within-run and machine-independent."""
    if model.get("schema") != "heeperator-model-v1":
        failures.append(f"model summary has schema {model.get('schema')!r}, "
                        "expected heeperator-model-v1")
        return
    res, sta = model.get("resident", {}), model.get("staged", {})
    print(f"model: {model.get('graph')} tiles={model.get('tiles')} "
          f"pipeline={model.get('pipeline')} — resident {res.get('cycles')} cycles / "
          f"{res.get('dma_active_cycles')} DMA-active, "
          f"staged {sta.get('cycles')} cycles / {sta.get('dma_active_cycles')} DMA-active")
    if not res.get("resident_boundaries"):
        failures.append("model run kept no inter-layer boundary resident in tile SRAM")
    r_dma, s_dma = res.get("dma_active_cycles"), sta.get("dma_active_cycles")
    if r_dma is None or s_dma is None:
        failures.append("model summary lacks resident/staged dma_active_cycles")
    elif r_dma >= s_dma:
        failures.append(
            f"resident policy does not beat staged on DMA-active cycles: "
            f"{r_dma} >= {s_dma}"
        )
    else:
        print(f"model DMA savings: {s_dma - r_dma} cycles "
              f"({(s_dma - r_dma) / s_dma:.1%} of the staged baseline)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default=None)
    ap.add_argument("--serve", default=None)
    ap.add_argument("--live", action="append", default=[])
    ap.add_argument("--model", default=None)
    ap.add_argument("--diff", default=None)
    ap.add_argument("--bench-lines", default=None)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--out", required=True)
    ap.add_argument("--max-regress", type=float, default=0.10)
    ap.add_argument("--max-latency-regress", type=float, default=0.10)
    ap.add_argument("--min-speedup", type=float, default=None)
    ap.add_argument("--min-sim-speedup", type=float, default=None)
    ap.add_argument("--min-worker-speedup", type=float, default=None)
    args = ap.parse_args()
    if not args.scale and not args.serve and not args.model:
        ap.error("at least one of --scale / --serve / --model is required")

    scale = read_json(args.scale) if args.scale else {}
    serve = read_json(args.serve) if args.serve else None
    model = read_json(args.model) if args.model else None
    reports = list(scale.get("reports", []))
    aggregate = scale.get("aggregate_cycles")
    if aggregate is None:
        aggregate = sum(r.get("cycles", 0) for r in reports)
    if not args.scale and serve is not None:
        # Serve-only invocation: the deterministic simulated service
        # window is the aggregate the baseline gate compares.
        aggregate = serve.get("sim_cycles", 0)
    if not args.scale and serve is None and model is not None:
        # Model-only invocation: the resident run's deterministic
        # makespan is the aggregate the baseline gate compares.
        aggregate = model.get("resident", {}).get("cycles", 0)

    for m in read_jsonl(args.bench_lines) if args.bench_lines else []:
        if "median_ns" in m:
            reports.append(
                {
                    "id": m["id"],
                    "cycles": None,  # wall-clock benchmark, no simulated cycles
                    "wall_ms": round(m["median_ns"] / 1e6, 3),
                    "runs": m.get("runs"),
                }
            )
        else:  # rate line (e.g. simulated cycles per host second)
            reports.append(
                {
                    "id": m["id"],
                    "cycles": None,
                    "throughput_per_s": m.get("throughput_per_s"),
                    "unit": m.get("unit"),
                    "runs": m.get("runs"),
                }
            )

    failures = []
    sim_speedup = None
    if args.diff:
        sim_speedup = diff_timing_modes(reports, read_json(args.diff), failures)
        if args.min_sim_speedup is not None:
            if sim_speedup is None:
                failures.append("--min-sim-speedup given but no sim speedup could be measured")
            elif sim_speedup < args.min_sim_speedup:
                failures.append(
                    f"event-vs-cycle sim speedup {sim_speedup:.1f}x < {args.min_sim_speedup}x"
                )

    merged = {
        "schema": "heeperator-bench-v1",
        "timing": scale.get("timing"),
        "reports": reports,
        "aggregate_cycles": aggregate,
    }
    if sim_speedup is not None:
        merged["sim_speedup_event_vs_cycle"] = round(sim_speedup, 2)
    if serve is not None:
        merged["serve"] = serve
    live = [read_json(p) for p in args.live]
    if live:
        merged["serve_live"] = live
    if model is not None:
        merged["model"] = model
    with open(args.out, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}: {len(reports)} reports, aggregate {aggregate} simulated cycles")

    if args.min_speedup is not None:
        tiled = [r for r in reports if r.get("tiles") and r.get("speedup") is not None]
        if tiled:
            top = max(tiled, key=lambda r: r["tiles"])
            print(f"speedup at {top['tiles']} tiles: {top['speedup']:.2f}x (floor {args.min_speedup}x)")
            if top["speedup"] < args.min_speedup:
                failures.append(
                    f"speedup at {top['tiles']} tiles is {top['speedup']:.2f}x < {args.min_speedup}x"
                )

    try:
        baseline = read_json(args.baseline)
    except FileNotFoundError:
        baseline = None
    armed = baseline if baseline is not None and not baseline.get("bootstrap") else None
    if serve is not None:
        check_serve(serve, armed, args.max_latency_regress, failures)
    if live or args.min_worker_speedup is not None:
        check_live(live, args.min_worker_speedup, failures)
    if model is not None:
        check_model(model, failures)
    base_cycles = None if baseline is None else baseline.get("aggregate_cycles")
    if baseline is None or baseline.get("bootstrap") or not base_cycles:
        print("no armed baseline: recording only (the workflow caches this run's "
              "BENCH_6.json as the baseline; commit one to pin it instead)")
    else:
        delta = (aggregate - base_cycles) / base_cycles
        print(f"aggregate cycles: {aggregate} vs baseline {base_cycles} ({delta:+.1%})")
        if delta > args.max_regress:
            failures.append(
                f"aggregate simulated cycles regressed {delta:.1%} > {args.max_regress:.0%}"
            )
        elif delta < -args.max_regress:
            print("note: large improvement — consider ratcheting the committed baseline")

    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
