//! Biosignal peak detection on NM-Caesar — the paper's motivating
//! area-critical use case ("min/max search algorithms for peak detection
//! [12]" in AI-based biomedical kernels, §I).
//!
//! A synthetic ECG-like 16-bit trace is searched for R-peaks: NM-Caesar
//! computes the global maximum with a packed `MAX` reduction tree streamed
//! by the DMA, the host derives a threshold from it and then only scans the
//! handful of supra-threshold candidates. Compared against the classic
//! CPU-only linear scan.
//!
//! Run with: `cargo run --release --example peak_detection`

use nmc::asm::Asm;
use nmc::bus::{periph, BANK_SIZE, CAESAR_BASE, PERIPH_BASE};
use nmc::caesar::compiler::CaesarProgram;
use nmc::isa::reg::*;
use nmc::isa::Sew;
use nmc::soc::Soc;

/// Synthetic ECG-ish trace: baseline noise + periodic sharp peaks.
fn waveform(n: usize) -> Vec<i16> {
    let mut rng = nmc::kernels::golden::Rng(0xec60);
    (0..n)
        .map(|i| {
            let noise = (rng.next_u32() % 200) as i16 - 100;
            let phase = i % 500;
            if (240..260).contains(&phase) {
                // R-peak ramp.
                let d = (250i32 - phase as i32).abs();
                (8000 - 600 * d) as i16 + noise
            } else {
                noise
            }
        })
        .collect()
}

fn cpu_only(signal: &[i16]) -> (u64, Vec<usize>) {
    let mut soc = Soc::heeperator();
    let bytes: Vec<u8> = signal.iter().flat_map(|v| v.to_le_bytes()).collect();
    soc.load_data(BANK_SIZE, &bytes);
    // max scan + second pass collecting indexes above 3/4 max.
    let mut a = Asm::new(0);
    a.li(A0, BANK_SIZE as i32)
        .li(A1, (BANK_SIZE + bytes.len() as u32) as i32)
        .li(A2, -32768)
        .label("scan")
        .lh(T0, 0, A0)
        .bge(A2, T0, "skip")
        .mv(A2, T0)
        .label("skip")
        .addi(A0, A0, 2)
        .bne(A0, A1, "scan")
        // threshold = max - max/4
        .srai(T1, A2, 2)
        .sub(A2, A2, T1)
        .li(A0, BANK_SIZE as i32)
        .li(A3, (2 * BANK_SIZE) as i32) // candidate list
        .label("scan2")
        .lh(T0, 0, A0)
        .blt(T0, A2, "no")
        .sw(A0, 0, A3)
        .addi(A3, A3, 4)
        .label("no")
        .addi(A0, A0, 2)
        .bne(A0, A1, "scan2")
        .ebreak();
    soc.load_firmware(&a.assemble().unwrap(), 0);
    soc.reset_stats();
    let (_h, cycles) = soc.run(10_000_000);
    let count = (soc.cpu.regs[A3 as usize] - 2 * BANK_SIZE) / 4;
    let idx = (0..count)
        .map(|i| {
            let addr = u32::from_le_bytes(
                soc.dump(2 * BANK_SIZE + 4 * i, 4).try_into().unwrap(),
            );
            ((addr - BANK_SIZE) / 2) as usize
        })
        .collect();
    (cycles, idx)
}

fn with_caesar(signal: &[i16]) -> (u64, Vec<usize>) {
    let mut soc = Soc::heeperator();
    let bytes: Vec<u8> = signal.iter().flat_map(|v| v.to_le_bytes()).collect();
    // Halves staged in opposite banks for cross-bank MAX folding.
    let words = bytes.len() as u32 / 4;
    soc.caesar_mut().load(0, &bytes[..bytes.len() / 2]);
    soc.caesar_mut().load(16 * 1024, &bytes[bytes.len() / 2..]);
    // The same data also sits in system RAM for the candidate scan (the
    // signal is memory-mapped either way; Caesar *is* a RAM bank).
    soc.load_data(BANK_SIZE, &bytes);

    // MAX reduction: fold halves, then fold within bank 0 (3-cycle ops).
    let mut p = CaesarProgram::new();
    p.csrw(Sew::E16);
    let half = words / 2;
    for i in 0..half {
        p.max(2048 + i, i, 4096 + i);
    }
    let mut len = half;
    let base = 2048;
    while len > 1 {
        let h = len / 2;
        for i in 0..h {
            p.max(base + i, base + i, base + h + i);
        }
        if len % 2 == 1 {
            p.max(base, base, base + len - 1);
        }
        len = h;
    }
    let stream = p.to_stream(CAESAR_BASE);
    soc.load_data(3 * BANK_SIZE, &stream);

    let mut a = Asm::new(0);
    a.li(T0, (PERIPH_BASE + periph::CAESAR_IMC) as i32)
        .li(T1, 1)
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_SRC) as i32)
        .li(T1, (3 * BANK_SIZE) as i32)
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_LEN) as i32)
        .li(T1, p.stream_len() as i32)
        .sw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::DMA_CTL) as i32)
        .li(T1, 0b11)
        .sw(T1, 0, T0)
        .wfi()
        .li(T0, (PERIPH_BASE + periph::DMA_STATUS) as i32)
        .lw(T1, 0, T0)
        .li(T0, (PERIPH_BASE + periph::CAESAR_IMC) as i32)
        .sw(ZERO, 0, T0)
        // Read the folded word; elementwise max of its two 16-bit lanes.
        .li(T0, (CAESAR_BASE + 2048 * 4) as i32)
        .lh(A2, 0, T0)
        .lh(T1, 2, T0)
        .bge(A2, T1, "m")
        .mv(A2, T1)
        .label("m")
        .srai(T1, A2, 2)
        .sub(A2, A2, T1) // threshold
        .li(A0, BANK_SIZE as i32)
        .li(A1, (BANK_SIZE + bytes.len() as u32) as i32)
        .li(A3, (2 * BANK_SIZE) as i32)
        .label("scan2")
        .lh(T0, 0, A0)
        .blt(T0, A2, "no")
        .sw(A0, 0, A3)
        .addi(A3, A3, 4)
        .label("no")
        .addi(A0, A0, 2)
        .bne(A0, A1, "scan2")
        .ebreak();
    soc.load_firmware(&a.assemble().unwrap(), 0);
    soc.reset_stats();
    let (_h, cycles) = soc.run(10_000_000);
    let count = (soc.cpu.regs[A3 as usize] - 2 * BANK_SIZE) / 4;
    let idx = (0..count)
        .map(|i| {
            let addr = u32::from_le_bytes(
                soc.dump(2 * BANK_SIZE + 4 * i, 4).try_into().unwrap(),
            );
            ((addr - BANK_SIZE) / 2) as usize
        })
        .collect();
    (cycles, idx)
}

fn main() {
    let n = 8192; // 16 KiB of 16-bit samples
    let sig = waveform(n);
    let (c_cpu, idx_cpu) = cpu_only(&sig);
    let (c_czr, idx_czr) = with_caesar(&sig);
    assert_eq!(idx_cpu, idx_czr, "both paths find the same peaks");
    // Group adjacent candidates into peaks.
    let mut peaks = 0;
    let mut last = usize::MAX - 10;
    for &i in &idx_cpu {
        if i > last + 5 {
            peaks += 1;
        } else if last == usize::MAX - 10 {
            peaks += 1;
        }
        last = i;
    }
    println!("signal: {n} samples, {} supra-threshold candidates, ~{peaks} peaks", idx_cpu.len());
    println!("CPU-only scan:        {c_cpu} cycles");
    println!("NM-Caesar reduction:  {c_czr} cycles  ({:.1}x faster)", c_cpu as f64 / c_czr as f64);
    assert!(c_czr < c_cpu);
}
