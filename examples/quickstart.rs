//! Quickstart: the smallest end-to-end NM-Carus program.
//!
//! Builds a HEEPerator system, writes two vectors into the NM-Carus macro
//! (which the host sees as a plain 32 KiB SRAM bank), uploads a three-
//! instruction xvnmc kernel into the 512 B eMEM, runs it, and reads the
//! result back over the bus — the paper's "drop-in compute memory" flow.
//!
//! Run with: `cargo run --release --example quickstart`

use nmc::asm::Asm;
use nmc::isa::reg::*;
use nmc::isa::Sew;
use nmc::soc::Soc;

fn main() {
    let mut soc = Soc::heeperator();

    // 1. The host populates its "memory": two int32 vectors of 64 elements.
    //    (Logical vector registers are vl·4 bytes; v0 and v1 here.)
    let vl = 64u32;
    for j in 0..vl {
        soc.carus_mut().vrf.set_elem(0, j, vl, Sew::E32, 3 * j);
        soc.carus_mut().vrf.set_elem(1, j, vl, Sew::E32, 1000 + j);
    }

    // 2. The xvnmc kernel: v2 = v0 + v1. Three instructions + ebreak.
    let mut k = Asm::new(0);
    k.li(A0, vl as i32)
        .vsetvli(T0, A0, Sew::E32)
        .vadd_vv(2, 0, 1)
        .ebreak();
    soc.carus_mut().load_kernel(&k.assemble().unwrap().words);

    // 3. Host firmware: configuration mode → start → wfi → ack.
    use nmc::bus::{periph, CARUS_BASE, PERIPH_BASE};
    let mut fw = Asm::new(0);
    fw.li(T0, (PERIPH_BASE + periph::CARUS_MODE) as i32)
        .li(T1, 1)
        .sw(T1, 0, T0)
        .li(A0, (CARUS_BASE + nmc::carus::CTL_OFFSET) as i32)
        .li(T1, nmc::carus::CTL_START as i32)
        .sw(T1, 0, A0)
        .wfi()
        .sw(ZERO, 0, A0)
        .sw(ZERO, 0, T0)
        .ebreak();
    soc.load_firmware(&fw.assemble().unwrap(), 0);
    soc.reset_stats();
    let (halt, cycles) = soc.run(100_000);

    // 4. Results, straight out of the memory bank.
    println!("halt = {halt:?} after {cycles} cycles");
    let mut ok = true;
    for j in 0..vl {
        let got = soc.carus().vrf.elem_unsigned(2, j, vl, Sew::E32);
        ok &= got == 1000 + 4 * j;
    }
    println!("v2 = v0 + v1: {}", if ok { "correct" } else { "WRONG" });
    let e = soc.energy();
    println!(
        "energy: {:.1} pJ total ({:.1} pJ/element), avg power {:.2} mW @ 250 MHz",
        e.total(),
        e.total() / vl as f64,
        e.avg_power_mw(soc.cycle)
    );
    assert!(ok);
}
