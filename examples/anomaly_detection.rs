//! End-to-end driver (the DESIGN.md validation workload): the MLPerf-Tiny
//! Anomaly-Detection autoencoder on every Table VI system configuration,
//! verified three ways:
//!
//! 1. every simulated system's output equals the Rust golden reference;
//! 2. (when `make artifacts` has run) the AOT-compiled JAX/Pallas model —
//!    executed from Rust through PJRT — produces the same bits;
//! 3. the cycle/energy/area numbers are printed against the paper's
//!    Table VI ratios.
//!
//! Run with: `cargo run --release --example anomaly_detection`

use nmc::apps::anomaly;
use nmc::area;
use nmc::kernels::Target;
use nmc::runtime::{artifacts_available, Runtime, TensorI32};
use nmc::sweep::SweepSession;

fn main() {
    let m = anomaly::model(2);
    let golden = anomaly::golden_forward(&m);
    println!(
        "Anomaly Detection autoencoder: {} layers, {} MACs, int8 (mod-256 semantics)",
        anomaly::network().len(),
        anomaly::total_macs()
    );

    // --- golden cross-check against the AOT JAX/Pallas artifact ------------
    // Skips gracefully when the artifacts are not built or the crate was
    // compiled without a PJRT backend (the offline, std-only build).
    match (artifacts_available(), Runtime::new()) {
        (true, Ok(mut rt)) => {
            let mut inputs =
                vec![TensorI32::new(m.input.iter().map(|&v| v as i32).collect(), &[640])];
            for (l, &(ins, outs, _)) in anomaly::network().iter().enumerate() {
                inputs.push(TensorI32::new(
                    m.weights[l].iter().map(|&v| v as i32).collect(),
                    &[outs as i64, ins as i64],
                ));
            }
            let xla = rt.execute("ad_autoencoder", &inputs).expect("AD artifact");
            let gold_i32: Vec<i32> = golden.iter().map(|&v| v as i32).collect();
            assert_eq!(xla, gold_i32);
            println!("XLA golden model (Pallas→HLO→PJRT): output matches the Rust reference ✓");
        }
        (false, _) => {
            println!("(artifacts not built — run `make artifacts` for the XLA cross-check)")
        }
        (true, Err(e)) => println!("(XLA cross-check skipped: {e})"),
    }

    // --- the five system configurations ------------------------------------
    // Simulated through the session (the same memoized path `heeperator
    // table6` / `ad` use; the multicore rows are derived projections).
    let session = SweepSession::new();
    let single = session.anomaly(Target::Cpu, 2);
    let configs = vec![
        single.as_ref().clone(),
        anomaly::scale_multicore(&single, 2),
        anomaly::scale_multicore(&single, 4),
        session.anomaly(Target::Caesar, 2).as_ref().clone(),
        session.anomaly(Target::Carus, 2).as_ref().clone(),
    ];
    let areas = [
        area::system_cpu_cluster(1),
        area::system_cpu_cluster(2),
        area::system_cpu_cluster(4),
        area::system_nmc(&area::caesar()),
        area::system_nmc(&area::carus(4)),
    ];
    println!();
    println!(
        "{:<22} {:>10} {:>9} {:>11} {:>8} {:>12}  output",
        "config", "cycles", "speedup", "energy[uJ]", "egain", "area[um2]"
    );
    for (i, res) in configs.iter().enumerate() {
        let verified = res.output == golden;
        println!(
            "{:<22} {:>10} {:>8.2}x {:>11.2} {:>7.2}x {:>12.0}  {}",
            res.name,
            res.cycles,
            single.cycles as f64 / res.cycles as f64,
            res.energy_uj,
            single.energy_uj / res.energy_uj,
            areas[i],
            if verified { "✓" } else { "MISMATCH" }
        );
        assert!(verified, "{} output mismatch", res.name);
    }
    println!();
    println!("paper Table VI: dual 2.00x/1.37x; quad 4.00x/1.67x; NM-Caesar 1.29x/1.20x; NM-Carus 3.55x/2.36x");
    println!("inference latency (250 MHz): {:.2} ms single-core → {:.2} ms on NM-Carus",
        single.cycles as f64 * 4.0 / 1e6,
        configs[4].cycles as f64 * 4.0 / 1e6);
}
