//! Fig. 12 sweep: matmul throughput/energy scaling of NM-Caesar vs NM-Carus
//! vs the CPU baseline over the matrix size P ([8,8]×[8,P]).
//!
//! Shows the paper's key architectural trade-off: NM-Caesar's 5-cycle
//! offload keeps its gain flat down to tiny matrices, while NM-Carus's
//! CPU-based controller needs larger workloads to amortize its bootstrap
//! but saturates at ≈0.48 output/cycle — 2× NM-Caesar's 0.25.
//!
//! All points drain through one `SweepSession`, the same memoizing path
//! the report harness uses — re-requesting a point is free.
//!
//! Run with: `cargo run --release --example matmul_sweep`

use nmc::isa::Sew;
use nmc::kernels::{Kernel, Target};
use nmc::sweep::SweepSession;

fn main() {
    let session = SweepSession::new();
    println!("{:>5} {:>7} | {:>12} {:>12} | {:>12} {:>12} | {:>12}", "P", "width", "caesar o/c", "caesar pJ/o", "carus o/c", "carus pJ/o", "cpu o/c");
    for sew in Sew::ALL {
        let pmax = 1024 / sew.bytes();
        for p in [8u32, 16, 32, 64, 128, 256, 512, 1024] {
            if p > pmax {
                continue;
            }
            let caesar = session.run(Target::Caesar, Kernel::Matmul { p }, sew, 3);
            let carus = session.run(Target::Carus, Kernel::Matmul { p }, sew, 3);
            let cpu = session.run(Target::Cpu, Kernel::Matmul { p }, sew, 3);
            println!(
                "{:>5} {:>7} | {:>12.3} {:>12.1} | {:>12.3} {:>12.1} | {:>12.3}",
                p,
                format!("{sew}"),
                caesar.outputs as f64 / caesar.cycles as f64,
                caesar.energy_per_output_pj(),
                carus.outputs as f64 / carus.cycles as f64,
                carus.energy_per_output_pj(),
                cpu.outputs as f64 / cpu.cycles as f64,
            );
        }
    }
    println!("\npaper saturation (8-bit): NM-Carus 0.48 out/cycle, 66 pJ/out; NM-Caesar 0.25 out/cycle, 175 pJ/out");
    println!("({} grid points simulated once each through the sweep session)", session.simulations());
}
