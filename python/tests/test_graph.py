"""Cross-language parity for the graph IR: the pure-Python mirror in
`compile/graph.py` must compile a Python-defined model to the byte-exact
schedule the Rust side produces (`rust/src/graph/mod.rs`), locked by the
shared fixture `ci/golden/model_schedule.txt`."""

from pathlib import Path

import pytest

from compile import graph

FIXTURE = Path(__file__).resolve().parents[2] / "ci" / "golden" / "model_schedule.txt"


def test_schedule_render_matches_rust_fixture_byte_for_byte():
    g = graph.Graph.parse(graph.CANONICAL, sew=8, seed=7)
    sch = graph.compile(g, tiles=2, pipeline="layer")
    assert sch.render() == FIXTURE.read_text()


def test_parse_infers_shapes_like_rust():
    g = graph.Graph.parse(graph.CANONICAL, sew=8, seed=7)
    assert g.layers == [
        graph.Kernel("matmul", 0, 32, 0),
        graph.Kernel("add", 256, 0, 0),
        graph.Kernel("relu", 256, 0, 0),
        graph.Kernel("maxpool", 16, 0, 0),
    ]
    assert g.input_elems() == 64
    assert g.output_elems() == 64
    # The canonical spec string round-trips.
    assert graph.Graph.parse(g.spec_string(), sew=8).layers == g.layers


def test_entry_layer_falls_back_to_paper_defaults():
    g = graph.Graph.parse("matmul", sew=8)
    assert g.layers[0].p == 1024
    g = graph.Graph.parse("relu", sew=16)
    assert g.layers[0].n == 8192


def test_parse_rejects_like_rust():
    with pytest.raises(graph.GraphError, match="empty graph"):
        graph.Graph.parse("", sew=8)
    with pytest.raises(graph.GraphError, match="unknown kernel"):
        graph.Graph.parse("blur", sew=8)
    with pytest.raises(graph.GraphError, match="entry layer"):
        graph.Graph.parse("relu:n=256,matmul:p=8", sew=8)
    with pytest.raises(graph.GraphError, match="n=100 contradicts the inferred shape n=256"):
        graph.Graph.parse("matmul:p=32,add:n=100", sew=8)
    with pytest.raises(graph.GraphError, match="16-row input, got 24"):
        graph.Graph.parse("relu:n=24,maxpool", sew=8)
    with pytest.raises(graph.GraphError, match="invalid shape"):
        graph.Graph.parse("add:n=6", sew=8)


def test_compile_assigns_boundaries_and_tiles_like_rust():
    g = graph.Graph.parse(graph.CANONICAL, sew=8, seed=7)
    sch = graph.compile(g, tiles=2, pipeline="layer")
    assert [l.boundary for l in sch.layers] == ["entry", "resident", "resident", "resident"]
    assert [l.tile for l in sch.layers] == [0, 1, 0, 1]
    assert sch.boundary_counts() == (3, 0)

    sch = graph.compile(g, tiles=2, pipeline="batch")
    assert all(l.tile is None for l in sch.layers)
    assert "tile=item" in sch.render()

    # A maxpool producer forces the staged fallback for its consumer.
    g = graph.Graph.parse("matmul:p=32,maxpool,relu", sew=8, seed=7)
    sch = graph.compile(g, tiles=2, pipeline="layer")
    assert sch.layers[2].boundary == "staged"
    assert sch.boundary_counts() == (1, 1)


def test_compile_rejects_unaligned_chunks_like_rust():
    # maxpool n=12 at 8 bit: the valid half-row prefix (6 B) cannot DMA.
    g = graph.Graph.parse("maxpool:n=12", sew=8)
    with pytest.raises(graph.GraphError, match=r"chunk \(0, 6\) is not word-aligned"):
        graph.compile(g, tiles=1, pipeline="layer")
