"""Pallas kernels vs pure-jnp references: bit-exact over shape/dtype sweeps.

Hypothesis drives the shapes/dtypes/values; assertions are exact equality
(integer kernels). This is the CORE correctness signal for Layer 1.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise as ew
from compile.kernels import matmul as mmk
from compile.kernels import ref

DTYPES = [np.int8, np.int16, np.int32]


def arr(draw, shape, dtype):
    info = np.iinfo(dtype)
    data = draw(
        st.lists(
            st.integers(int(info.min), int(info.max)),
            min_size=int(np.prod(shape)),
            max_size=int(np.prod(shape)),
        )
    )
    return np.array(data, dtype=dtype).reshape(shape)


@st.composite
def ew_case(draw):
    dtype = draw(st.sampled_from(DTYPES))
    n = draw(st.integers(1, 600))
    return arr(draw, (n,), dtype), arr(draw, (n,), dtype)


@settings(max_examples=20, deadline=None)
@given(ew_case())
def test_elementwise_ops(case):
    a, b = case
    np.testing.assert_array_equal(np.asarray(ew.xor(a, b)), np.asarray(ref.xor(a, b)))
    np.testing.assert_array_equal(np.asarray(ew.add(a, b)), np.asarray(ref.add(a, b)))
    np.testing.assert_array_equal(np.asarray(ew.mul(a, b)), np.asarray(ref.mul(a, b)))


@settings(max_examples=15, deadline=None)
@given(ew_case())
def test_activations(case):
    a, _ = case
    np.testing.assert_array_equal(np.asarray(ew.relu(a)), np.asarray(ref.relu(a)))
    np.testing.assert_array_equal(
        np.asarray(ew.leaky_relu(a)), np.asarray(ref.leaky_relu(a))
    )


@st.composite
def mm_case(draw):
    dtype = draw(st.sampled_from(DTYPES))
    m = draw(st.integers(1, 8))
    k = draw(st.integers(1, 8))
    p = draw(st.sampled_from([1, 7, 64, 128, 130, 256]))
    return arr(draw, (m, k), dtype), arr(draw, (k, p), dtype)


@settings(max_examples=15, deadline=None)
@given(mm_case())
def test_matmul(case):
    a, b = case
    got = np.asarray(mmk.matmul(a, b, out_dtype=a.dtype))
    want = np.asarray(ref.matmul(a, b, a.dtype))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(mm_case())
def test_gemm(case):
    a, b = case
    rng = np.random.default_rng(0)
    c = rng.integers(-100, 100, size=(a.shape[0], b.shape[1])).astype(a.dtype)
    got = np.asarray(mmk.gemm(a, b, c, out_dtype=a.dtype))
    want = np.asarray(ref.gemm(a, b, c, a.dtype))
    np.testing.assert_array_equal(got, want)


@st.composite
def conv_case(draw):
    dtype = draw(st.sampled_from(DTYPES))
    f = draw(st.sampled_from([2, 3, 4]))
    rows = draw(st.integers(f, 8))
    n = draw(st.integers(f, 40))
    return arr(draw, (rows, n), dtype), arr(draw, (f, f), dtype), f


@settings(max_examples=12, deadline=None)
@given(conv_case())
def test_conv2d(case):
    img, filt, f = case
    got = np.asarray(ew.conv2d(img, filt, f=f))
    want = np.asarray(ref.conv2d(img, filt, img.dtype))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(DTYPES), st.integers(1, 8), st.integers(1, 32), st.integers(0, 2**32 - 1))
def test_maxpool(dtype, hr, hc, seed):
    rng = np.random.default_rng(seed)
    info = np.iinfo(dtype)
    img = rng.integers(info.min, int(info.max) + 1, size=(2 * hr, 2 * hc)).astype(dtype)
    got = np.asarray(ew.maxpool2x2(img))
    want = np.asarray(ref.maxpool2x2(img))
    np.testing.assert_array_equal(got, want)


def test_matvec_is_matmul_transposed():
    rng = np.random.default_rng(7)
    w = rng.integers(-128, 128, size=(128, 640)).astype(np.int8)
    x = rng.integers(-128, 128, size=(640,)).astype(np.int8)
    got = np.asarray(mmk.matvec(w, x))
    want = (w.astype(np.int32) @ x.astype(np.int32)).astype(np.int8)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dtype", DTYPES)
def test_wrap_semantics_match_rust(dtype):
    # 8-bit: 127*2 wraps to -2 etc. Mirrors rust golden::wrap tests.
    a = np.array([np.iinfo(dtype).max], dtype=dtype)
    b = np.array([2], dtype=dtype)
    got = np.asarray(ew.mul(a, b))
    assert got[0] == np.multiply(a, b, dtype=dtype)[0]
