"""Layer-2 model tests: the AD autoencoder through the Pallas kernels vs the
pure-jnp reference + numpy mod-256 semantics."""

import numpy as np
import jax.numpy as jnp

from compile import model


def random_model(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, size=(640,)).astype(np.int32)
    ws = [
        rng.integers(-128, 128, size=(o, i)).astype(np.int32)
        for (i, o, _) in model.LAYERS
    ]
    return x, ws


def numpy_forward(x, ws):
    a = x.astype(np.int8)
    for (i, o, relu), w in zip(model.LAYERS, ws):
        acc = w.astype(np.int32) @ a.astype(np.int32)
        y = acc.astype(np.int8)
        if relu:
            y = np.maximum(y, 0)
        a = y
    return a.astype(np.int32)


def test_pallas_fwd_matches_numpy():
    x, ws = random_model(1)
    got = np.asarray(model.autoencoder_fwd(jnp.asarray(x), *map(jnp.asarray, ws)))
    want = numpy_forward(x, ws)
    np.testing.assert_array_equal(got, want)


def test_pallas_fwd_matches_jnp_ref():
    x, ws = random_model(2)
    got = np.asarray(model.autoencoder_fwd(jnp.asarray(x), *map(jnp.asarray, ws)))
    ref = np.asarray(model.autoencoder_ref(jnp.asarray(x), *map(jnp.asarray, ws)))
    np.testing.assert_array_equal(got, ref)


def test_shapes():
    x, ws = random_model(3)
    y = np.asarray(model.autoencoder_fwd(jnp.asarray(x), *map(jnp.asarray, ws)))
    assert y.shape == (640,)
    assert y.dtype == np.int32
    # int8 range preserved through the i32 interface.
    assert y.min() >= -128 and y.max() <= 127


def test_relu_layers_nonnegative():
    # Probe an intermediate: run a single relu layer manually.
    rng = np.random.default_rng(4)
    w = rng.integers(-128, 128, size=(128, 640)).astype(np.int32)
    x = rng.integers(-128, 128, size=(640,)).astype(np.int32)
    from compile.kernels import matmul as mmk

    y = np.asarray(mmk.matvec(w.astype(np.int8), x.astype(np.int8), out_dtype=np.int8))
    y = np.maximum(y, 0)
    assert (y >= 0).all()
