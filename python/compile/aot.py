"""AOT compile path: lower every kernel/model to HLO text artifacts.

Usage (from ``python/``):  python -m compile.aot --out ../artifacts

HLO **text** (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact's interface is int32 (values within the kernel's SEW range);
casts happen inside the graph — the Rust PJRT wrapper marshals i32 literals
only. Each lowered function returns a 1-tuple (``return_tuple=True``), so
the Rust side unwraps with ``to_tuple1``.

A ``manifest.json`` records name → {shapes, sew, kind} for the Rust-side
golden-runtime tests.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import elementwise as ew
from .kernels import matmul as mmk

SEWS = {"e8": (jnp.int8, 1), "e16": (jnp.int16, 2), "e32": (jnp.int32, 4)}


def to_hlo_text(fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_artifacts():
    """Yield (name, fn, example_args, manifest_entry)."""
    arts = []

    # --- matmul / GEMM: paper CPU/Carus shapes (footnotes b, c) -----------
    for sew, (dt, sb) in SEWS.items():
        p = {1: 1024, 2: 512, 4: 256}[sb]

        def mm(a, b, dt=dt):
            c = mmk.matmul(a.astype(dt), b.astype(dt), out_dtype=dt)
            return (c.astype(jnp.int32),)

        arts.append((f"matmul_{sew}", mm, (i32((8, 8)), i32((8, p))),
                     {"kind": "matmul", "sew": sew, "p": p}))

        def gm(a, b, c, dt=dt):
            r = mmk.gemm(a.astype(dt), b.astype(dt), c.astype(dt), out_dtype=dt)
            return (r.astype(jnp.int32),)

        arts.append((f"gemm_{sew}", gm, (i32((8, 8)), i32((8, p)), i32((8, p))),
                     {"kind": "gemm", "sew": sew, "p": p}))

    # --- conv2d: 8×n image, f=3 (footnote d, CPU/Carus) --------------------
    for sew, (dt, sb) in SEWS.items():
        n = {1: 1024, 2: 512, 4: 256}[sb]

        def cv(img, filt, dt=dt):
            r = ew.conv2d(img.astype(dt), filt.astype(dt), f=3)
            return (r.astype(jnp.int32),)

        arts.append((f"conv2d_{sew}", cv, (i32((8, n)), i32((3, 3))),
                     {"kind": "conv2d", "sew": sew, "n": n, "f": 3}))

    # --- element-wise: 10 KiB inputs (footnote a) ---------------------------
    for sew, (dt, sb) in SEWS.items():
        n = 5120 // sb
        for kind, fn in [("xor", ew.xor), ("add", ew.add), ("mul", ew.mul)]:

            def f(a, b, fn=fn, dt=dt):
                return (fn(a.astype(dt), b.astype(dt)).astype(jnp.int32),)

            arts.append((f"{kind}_{sew}", f, (i32((n,)), i32((n,))),
                         {"kind": kind, "sew": sew, "n": n}))

    # --- activations: 16 KiB input (footnote e) ----------------------------
    for sew, (dt, sb) in SEWS.items():
        n = 16384 // sb
        for kind, fn in [("relu", ew.relu), ("leaky_relu", ew.leaky_relu)]:

            def f(a, fn=fn, dt=dt):
                return (fn(a.astype(dt)).astype(jnp.int32),)

            arts.append((f"{kind}_{sew}", f, (i32((n,)),),
                         {"kind": kind, "sew": sew, "n": n}))

    # --- maxpool: 16×n image (footnote g) -----------------------------------
    for sew, (dt, sb) in SEWS.items():
        n = 16384 // 16 // sb

        def f(img, dt=dt):
            return (ew.maxpool2x2(img.astype(dt)).astype(jnp.int32),)

        arts.append((f"maxpool_{sew}", f, (i32((16, n)),),
                     {"kind": "maxpool", "sew": sew, "n": n}))

    # --- the end-to-end model ------------------------------------------------
    def ad(x, *ws):
        return (model.autoencoder_fwd(x, *ws),)

    arts.append(("ad_autoencoder", ad, model.example_args(),
                 {"kind": "ad", "layers": model.LAYERS}))
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {}
    for name, fn, ex, meta in build_artifacts():
        if args.only and args.only not in name:
            continue
        text = to_hlo_text(fn, ex)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["args"] = [list(a.shape) for a in ex]
        manifest[name] = meta
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(manifest)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
