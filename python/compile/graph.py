"""Pure-Python mirror of the Rust graph IR (`rust/src/graph/mod.rs`).

A model defined here — a linear chain of the benchmark kernels at one
element width, e.g. ``matmul:p=32,add,relu,maxpool`` — compiles to the
*same schedule* as the Rust side: parse rules, shape inference, the
NM-Carus staging envelope, and the resident-vs-staged boundary decision
are all replicated, and :meth:`Schedule.render` is byte-identical to
``Schedule::render``. The shared fixture ``ci/golden/model_schedule.txt``
locks the parity in both test suites.

No third-party imports on purpose: the mirror is the portable spec of
the schedule, not a numerical library.
"""

from collections import namedtuple

#: NM-Carus logical register width (bytes) — `carus::REG_BYTES`.
REG_BYTES = 1024

#: Families whose only free dimension is ``n``.
_N_FAMILIES = ("xor", "add", "mul", "relu", "leakyrelu", "maxpool")

_ALIASES = {"conv": "conv2d", "leaky-relu": "leakyrelu", "leaky_relu": "leakyrelu"}

#: Kernel shape: family slug plus the (n, p, f) tuple, zeros for
#: dimensions the family does not use — mirrors `spec::shape_of`.
Kernel = namedtuple("Kernel", ["family", "n", "p", "f"])

Layer = namedtuple("Layer", ["kernel", "boundary", "tile", "elems_in", "elems_out"])


class GraphError(ValueError):
    """Graph spec or lowering error, attributed to a layer index."""


def paper_default(family, sew):
    """The paper's Table V shape for ``(NM-Carus, sew)`` —
    `Kernel::paper_default` with ``small = false``."""
    sb = sew // 8
    if family in ("xor", "add", "mul"):
        return Kernel(family, 10 * 1024 // 2 // sb, 0, 0)
    if family in ("matmul", "gemm"):
        return Kernel(family, 0, {32: 256, 16: 512, 8: 1024}[sew], 0)
    if family == "conv2d":
        return Kernel(family, {32: 256, 16: 512, 8: 1024}[sew], 0, 3)
    if family in ("relu", "leakyrelu"):
        return Kernel(family, 16 * 1024 // sb, 0, 0)
    assert family == "maxpool"
    return Kernel(family, 16 * 1024 // 16 // sb, 0, 0)


def with_shape(family, sew, n=None, p=None, f=None):
    """Fill unspecified free dimensions from the paper default."""
    d = paper_default(family, sew)
    if family in _N_FAMILIES:
        return d._replace(n=d.n if n is None else n)
    if family in ("matmul", "gemm"):
        return d._replace(p=d.p if p is None else p)
    return d._replace(n=d.n if n is None else n, f=d.f if f is None else f)


def in_elems(k):
    """Elements of the activation operand a kernel consumes."""
    if k.family in ("matmul", "gemm"):
        return 64
    if k.family == "conv2d":
        return 8 * k.n
    if k.family == "maxpool":
        return 16 * k.n
    return k.n


def out_elems(k):
    """Elements of the output tensor a kernel produces."""
    if k.family in ("matmul", "gemm"):
        return 8 * k.p
    if k.family == "conv2d":
        return (8 - k.f + 1) * (k.n - k.f + 1)
    if k.family == "maxpool":
        return 8 * (k.n // 2)
    return k.n


def output_chunks(k, sew):
    """(offset, length) byte spans of the valid output in the tile
    window — `carus::output_chunks`. One chunk ⇒ the consumer can take
    it resident; several ⇒ host-staged repack."""
    sb = sew // 8
    if k.family in ("xor", "add", "mul"):
        return [(20 * REG_BYTES, k.n * sb)]
    if k.family in ("relu", "leakyrelu"):
        return [(0, k.n * sb)]
    if k.family in ("matmul", "gemm"):
        return [(8 * k.p * sb, 8 * k.p * sb)]
    if k.family == "conv2d":
        rb = k.n * sb
        return [(8 * rb + r * rb, (k.n - k.f + 1) * sb) for r in range(8 - k.f + 1)]
    rb = k.n * sb
    return [(r * rb, (k.n // 2) * sb) for r in range(8)]


def validate(k, sew):
    """NM-Carus staging envelope — `Kernel::validate(Target::Carus)`.
    Raises ``GraphError`` on an impossible shape."""
    sb = sew // 8
    if k.family in ("xor", "add", "mul"):
        if k.n == 0 or (k.n * sb) % 4 != 0 or k.n * sb > 10 * 1024:
            raise GraphError(f"n = {k.n} out of NM-Carus range at {sew} bit")
    elif k.family in ("relu", "leakyrelu"):
        if k.n == 0 or (k.n * sb) % 4 != 0 or k.n * sb > 16 * 1024:
            raise GraphError(f"n = {k.n} out of NM-Carus range at {sew} bit")
    elif k.family in ("matmul", "gemm"):
        if k.p < 8 or (k.p * sb) % 4 != 0 or k.p * sb > REG_BYTES:
            raise GraphError(f"p = {k.p} out of NM-Carus range (8 <= p, p*sew <= 1024 B)")
    elif k.family == "conv2d":
        if k.n == 0 or k.f == 0 or k.f > 8 or k.f > k.n or k.n * sb > REG_BYTES:
            raise GraphError(f"conv2d shape n = {k.n}, f = {k.f} out of NM-Carus range")
    else:
        if k.n == 0 or k.n % 2 != 0 or (k.n * sb) % 4 != 0 or k.n * sb > REG_BYTES:
            raise GraphError(f"n = {k.n} must be positive, even, and word-aligned")


class Graph:
    """A validated linear kernel chain at one element width."""

    def __init__(self, layers, sew, seed):
        self.layers = layers
        self.sew = sew
        self.seed = seed

    @classmethod
    def parse(cls, spec, sew=8, seed=1):
        """Parse a graph spec — `Graph::parse`. Comma-separated layer
        clauses, each a family name plus optional ``:dim=value`` pairs;
        the entry layer falls back to Table V, later layers infer their
        shape from the producer."""
        if sew not in (8, 16, 32):
            raise GraphError(f"unknown sew {sew}")
        clauses = [c.strip() for c in spec.split(",")]
        if all(not c for c in clauses):
            raise GraphError("empty graph")
        layers = []
        for layer, clause in enumerate(clauses):
            fields = clause.split(":")
            name = fields[0].strip().lower()
            family = _ALIASES.get(name, name)
            if family not in _N_FAMILIES + ("matmul", "gemm", "conv2d"):
                raise GraphError(f"layer {layer}: unknown kernel `{name}`")
            dims = {}
            for kv in fields[1:]:
                key, sep, val = kv.partition("=")
                if not sep:
                    raise GraphError(f"layer {layer}: expected dim=value, got `{kv}`")
                key = key.strip()
                if key not in ("n", "p", "f"):
                    raise GraphError(f"layer {layer}: unknown dimension `{key}` (n, p, f)")
                try:
                    dims[key] = int(val.strip())
                except ValueError:
                    raise GraphError(f"layer {layer}: bad value in `{kv}`") from None
            if layer == 0:
                kernel = with_shape(family, sew, **dims)
            else:
                if family in ("matmul", "gemm", "conv2d"):
                    raise GraphError(
                        f"layer {layer}: {family} transforms its operands host-side "
                        "and is only legal as the entry layer"
                    )
                if "p" in dims or "f" in dims:
                    raise GraphError(f"layer {layer}: only the entry layer takes p/f")
                elems = out_elems(layers[layer - 1])
                if family == "maxpool":
                    if elems % 16 != 0:
                        raise GraphError(
                            f"layer {layer}: maxpool needs a 16-row input, got {elems}"
                        )
                    inferred = elems // 16
                else:
                    inferred = elems
                if dims.get("n", inferred) != inferred:
                    raise GraphError(
                        f"layer {layer}: explicit n={dims['n']} contradicts "
                        f"the inferred shape n={inferred}"
                    )
                kernel = Kernel(family, inferred, 0, 0)
            try:
                validate(kernel, sew)
            except GraphError as e:
                raise GraphError(f"layer {layer}: invalid shape: {e}") from None
            layers.append(kernel)
        return cls(layers, sew, seed)

    def spec_string(self):
        """Canonical spec string (round-trips through :meth:`parse`)."""
        clauses = []
        for i, k in enumerate(self.layers):
            s = k.family
            if i == 0:
                for key, v in (("n", k.n), ("p", k.p), ("f", k.f)):
                    if v != 0:
                        s += f":{key}={v}"
            clauses.append(s)
        return ",".join(clauses)

    def input_elems(self):
        return in_elems(self.layers[0])

    def output_elems(self):
        return out_elems(self.layers[-1])


class Schedule(namedtuple("Schedule", ["graph", "tiles", "pipeline", "layers"])):
    """A graph lowered onto a tile configuration."""

    def render(self):
        """Canonical textual rendering — byte-identical to the Rust
        ``Schedule::render`` and locked by ``ci/golden/model_schedule.txt``."""
        s = "# heeperator model schedule v1\n"
        s += (
            f"graph {self.graph.spec_string()} sew={self.graph.sew} "
            f"tiles={self.tiles} pipeline={self.pipeline}\n"
        )
        for i, l in enumerate(self.layers):
            k = l.kernel
            tile = "item" if l.tile is None else str(l.tile)
            s += (
                f"layer {i} {k.family} n={k.n} p={k.p} f={k.f} tile={tile} "
                f"in={l.boundary} elems_in={l.elems_in} elems_out={l.elems_out}\n"
            )
        return s

    def boundary_counts(self):
        """(resident, staged) inter-layer boundary counts."""
        resident = sum(1 for l in self.layers if l.boundary == "resident")
        staged = sum(1 for l in self.layers if l.boundary == "staged")
        return resident, staged


def compile(graph, tiles, pipeline):
    """Lower a graph onto ``tiles`` NM-Carus tiles — `graph::compile`.
    ``pipeline`` is ``"layer"`` or ``"batch"``."""
    assert tiles >= 1, "need at least one tile"
    assert pipeline in ("layer", "batch"), pipeline
    layers = []
    for layer, kernel in enumerate(graph.layers):
        for off, length in output_chunks(kernel, graph.sew):
            if off % 4 != 0 or length % 4 != 0 or length == 0:
                raise GraphError(
                    f"layer {layer}: output chunk ({off}, {length}) is not word-aligned"
                )
        if layer == 0:
            boundary = "entry"
        elif len(output_chunks(graph.layers[layer - 1], graph.sew)) == 1:
            boundary = "resident"
        else:
            boundary = "staged"
        tile = layer % tiles if pipeline == "layer" else None
        layers.append(Layer(kernel, boundary, tile, in_elems(kernel), out_elems(kernel)))
    return Schedule(graph, tiles, pipeline, layers)


#: The canonical demo chain — `graph::CANONICAL`.
CANONICAL = "matmul:p=32,add,relu,maxpool"
