"""Layer-2 JAX model: the Anomaly-Detection autoencoder.

The MLPerf-Tiny AD topology (640-128-128-128-128-8-128-128-128-128-640)
with int8 weights and the mod-256 accumulate semantics shared with the
simulator (`rust/src/apps/anomaly.rs::golden_forward`): each layer computes
`relu(wrap8(w @ x))`, last layer without ReLU.

The forward pass calls the Layer-1 Pallas matvec kernel, so the AOT-lowered
HLO exercises the full three-layer stack. The module interface uses int32
arrays (values in int8 range) because the PJRT interchange on the Rust side
marshals i32 literals; casts happen inside the graph.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul as mmk

# (in, out, relu) — keep in sync with rust/src/apps/anomaly.rs::network().
LAYERS = [
    (640, 128, True),
    (128, 128, True),
    (128, 128, True),
    (128, 128, True),
    (128, 8, True),
    (8, 128, True),
    (128, 128, True),
    (128, 128, True),
    (128, 128, True),
    (128, 640, False),
]


def autoencoder_fwd(x_i32, *weights_i32):
    """Forward pass. `x_i32`: (640,) int32 in [-128,127]; weights: one
    (out, in) int32 array per layer. Returns (640,) int32."""
    x = x_i32.astype(jnp.int8)
    assert len(weights_i32) == len(LAYERS)
    for (ins, outs, relu), w in zip(LAYERS, weights_i32):
        assert w.shape == (outs, ins), (w.shape, (outs, ins))
        y = mmk.matvec(w.astype(jnp.int8), x, out_dtype=jnp.int8)
        if relu:
            y = jnp.maximum(y, 0)
        x = y
    return x.astype(jnp.int32)


def autoencoder_ref(x_i32, *weights_i32):
    """Pure-jnp reference (no Pallas), for pytest cross-checking."""
    x = x_i32.astype(jnp.int8)
    for (ins, outs, relu), w in zip(LAYERS, weights_i32):
        acc = jnp.matmul(w.astype(jnp.int32), x.astype(jnp.int32))
        y = acc.astype(jnp.int8)
        if relu:
            y = jnp.maximum(y, 0)
        x = y
    return x.astype(jnp.int32)


def example_args():
    """ShapeDtypeStructs for AOT lowering."""
    x = jax.ShapeDtypeStruct((640,), jnp.int32)
    ws = [jax.ShapeDtypeStruct((o, i), jnp.int32) for (i, o, _) in LAYERS]
    return (x, *ws)
