"""Layer-1 Pallas kernels: element-wise / activation / pooling ops.

Each kernel mirrors one NM-Caesar micro-op stream or NM-Carus vector
instruction: data streams through in lane tiles (the HBM↔VMEM analogue of
the word-interleaved VRF banks), one vector op per tile. `interpret=True`
(CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE = 256


def _ew_call(body, *args):
    """Run an element-wise kernel body over a 1-D array in lane tiles."""
    n = args[0].shape[0]
    pad = (-n) % TILE
    padded = [jnp.pad(a, (0, pad)) for a in args]
    np_ = n + pad

    def kernel(*refs):
        ins = [r[...] for r in refs[:-1]]
        refs[-1][...] = body(*ins)

    out = pl.pallas_call(
        kernel,
        grid=(np_ // TILE,),
        in_specs=[pl.BlockSpec((TILE,), lambda j: (j,)) for _ in args],
        out_specs=pl.BlockSpec((TILE,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((np_,), args[0].dtype),
        interpret=True,
    )(*padded)
    return out[:n]


@jax.jit
def xor(a, b):
    return _ew_call(lambda x, y: x ^ y, a, b)


@jax.jit
def add(a, b):
    return _ew_call(lambda x, y: x + y, a, b)


@jax.jit
def mul(a, b):
    return _ew_call(lambda x, y: x * y, a, b)


@jax.jit
def relu(a):
    return _ew_call(lambda x: jnp.maximum(x, 0), a)


@jax.jit
def leaky_relu(a):
    return _ew_call(lambda x: jnp.where(x >= 0, x, x >> ref.LEAKY_SHIFT), a)


def _conv_kernel(img_ref, filt_ref, o_ref, *, f):
    # The Carus schedule: Σ over (dy, dx) of slide(img_row, dx) · w[dy,dx],
    # expressed as shifted-slice MACs with int32 accumulation.
    img = img_ref[...].astype(jnp.int32)
    filt = filt_ref[...].astype(jnp.int32)
    orows = img.shape[0] - f + 1
    ocols = img.shape[1] - f + 1
    acc = jnp.zeros((orows, ocols), jnp.int32)
    for dy in range(f):
        for dx in range(f):
            acc = acc + img[dy : dy + orows, dx : dx + ocols] * filt[dy, dx]
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("f",))
def conv2d(img, filt, f):
    """Valid 2D convolution A[rows,n] ⊛ F[f,f] (single block: the paper's
    images are 8×n and fit VMEM whole)."""
    rows, n = img.shape
    out = pl.pallas_call(
        functools.partial(_conv_kernel, f=f),
        out_shape=jax.ShapeDtypeStruct((rows - f + 1, n - f + 1), img.dtype),
        interpret=True,
    )(img, filt)
    return out


def _pool_kernel(img_ref, o_ref):
    img = img_ref[...]
    v = jnp.maximum(img[0::2, :], img[1::2, :])
    o_ref[...] = jnp.maximum(v[:, 0::2], v[:, 1::2])


@jax.jit
def maxpool2x2(img):
    r, c = img.shape
    return pl.pallas_call(
        _pool_kernel,
        out_shape=jax.ShapeDtypeStruct((r // 2, c // 2), img.dtype),
        interpret=True,
    )(img)
