"""Pure-jnp golden references for every benchmark kernel.

These are the correctness oracles for the Pallas kernels (pytest compares
bit-exactly) and the source of the AOT artifacts' semantics. They implement
exactly the simulator's arithmetic convention (see
``rust/src/kernels/golden.rs``): elements are 2's-complement integers of the
kernel SEW; accumulating kernels accumulate **mod 2^sew** — i.e. int32
accumulation truncated to the element dtype, which is equivalent to
wrap-at-each-step.
"""

import jax.numpy as jnp

# Leaky-ReLU negative-slope shift (slope 1/8), matching
# rust/src/kernels/golden.rs::LEAKY_SHIFT.
LEAKY_SHIFT = 3
# GEMM constants (rust golden::GEMM_ALPHA/BETA).
GEMM_ALPHA = 2
GEMM_BETA = 3


def xor(a, b):
    return a ^ b


def add(a, b):
    return a + b  # wrapping in integer dtypes


def mul(a, b):
    return a * b


def matmul(a, b, out_dtype):
    """A[8,8] x B[8,p], accumulate mod 2^sew (int32 accumulate + truncate)."""
    acc = jnp.matmul(a.astype(jnp.int32), b.astype(jnp.int32))
    return acc.astype(out_dtype)


def gemm(a, b, c, out_dtype):
    """alpha*(A@B) + beta*C mod 2^sew."""
    ab = jnp.matmul(a.astype(jnp.int32), b.astype(jnp.int32))
    acc = GEMM_ALPHA * ab + GEMM_BETA * c.astype(jnp.int32)
    return acc.astype(out_dtype)


def conv2d(img, filt, out_dtype):
    """Valid 2D convolution (cross-correlation, like the paper's kernels)."""
    rows, n = img.shape
    f = filt.shape[0]
    orows, ocols = rows - f + 1, n - f + 1
    acc = jnp.zeros((orows, ocols), jnp.int32)
    for dy in range(f):
        for dx in range(f):
            acc = acc + (
                img[dy : dy + orows, dx : dx + ocols].astype(jnp.int32)
                * filt[dy, dx].astype(jnp.int32)
            )
    return acc.astype(out_dtype)


def relu(a):
    return jnp.maximum(a, 0)


def leaky_relu(a):
    return jnp.where(a >= 0, a, a >> LEAKY_SHIFT)


def maxpool2x2(img):
    """2x2 max pooling, stride 2."""
    v = jnp.maximum(img[0::2, :], img[1::2, :])
    return jnp.maximum(v[:, 0::2], v[:, 1::2])


def ad_layer(w, x, apply_relu):
    """One Anomaly-Detection layer: relu(wrap8(w @ x)) with int8 weights.

    Bit-exact with rust/src/apps/anomaly.rs::golden_forward.
    """
    acc = jnp.matmul(w.astype(jnp.int32), x.astype(jnp.int32))
    y = acc.astype(jnp.int8)
    if apply_relu:
        y = jnp.maximum(y, 0)
    return y
