"""Layer-1 Pallas kernel: lane-tiled integer matmul (the NMC hot-spot).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): NM-Carus tiles the
B-matrix row vectors across word-interleaved VRF banks and drives one
serial MAC ALU per bank; on a TPU the same insight maps to tiling the
output columns across VMEM blocks and feeding the MXU with an
int8→int32 contraction. The `BlockSpec` below expresses exactly that
schedule: the A tile is resident (analogous to the splatted scalar
operands of `vmacc.vx`), B/C stream through in `TILE`-column blocks
(analogous to one VRF bank's word stream).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated on CPU and the TPU efficiency is
estimated analytically (DESIGN.md §8).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane-tile width: multiple of the TPU lane count (128) and of the NM-Carus
# logical-register granularity.
TILE = 128


def _mm_kernel(a_ref, b_ref, o_ref):
    # int32 accumulate (MXU-friendly), truncate to the output dtype — the
    # mod-2^sew semantics shared with the hardware datapath.
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    acc = jnp.dot(a, b, preferred_element_type=jnp.int32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def matmul(a, b, out_dtype=None):
    """C[m,p] = (A[m,k] @ B[k,p]) mod 2^sew, Pallas lane-tiled.

    Shapes: m, k arbitrary small (A stays resident); p padded to TILE.
    """
    out_dtype = out_dtype or a.dtype
    m, k = a.shape
    k2, p = b.shape
    assert k == k2
    pad = (-p) % TILE
    if pad:
        b = jnp.pad(b, ((0, 0), (0, pad)))
    pp = p + pad
    out = pl.pallas_call(
        _mm_kernel,
        grid=(pp // TILE,),
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k, TILE), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, TILE), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, pp), out_dtype),
        interpret=True,
    )(a, b)
    return out[:, :p]


def _gemm_kernel(a_ref, b_ref, c_ref, o_ref, *, alpha, beta):
    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    c = c_ref[...].astype(jnp.int32)
    acc = alpha * jnp.dot(a, b, preferred_element_type=jnp.int32) + beta * c
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "out_dtype"))
def gemm(a, b, c, alpha=2, beta=3, out_dtype=None):
    """alpha*(A@B) + beta*C mod 2^sew, same tiling as `matmul`."""
    out_dtype = out_dtype or a.dtype
    m, k = a.shape
    _, p = b.shape
    pad = (-p) % TILE
    if pad:
        b = jnp.pad(b, ((0, 0), (0, pad)))
        c = jnp.pad(c, ((0, 0), (0, pad)))
    pp = p + pad
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, alpha=alpha, beta=beta),
        grid=(pp // TILE,),
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k, TILE), lambda j: (0, j)),
            pl.BlockSpec((m, TILE), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, TILE), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, pp), out_dtype),
        interpret=True,
    )(a, b, c)
    return out[:, :p]


def matvec(w, x, out_dtype=None):
    """w[out,in] @ x[in] — the Anomaly-Detection layer primitive, expressed
    through the same lane-tiled kernel (x as a 1-column B with the roles
    swapped: out dimension tiled across lanes)."""
    out_dtype = out_dtype or w.dtype
    # (1, in) @ (in, out) keeps the big dimension on the lane axis.
    y = matmul(x[None, :], w.T, out_dtype=out_dtype)
    return y[0]
